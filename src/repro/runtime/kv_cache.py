"""Paged KV-cache manager for decode serving (vLLM-style, TT-scale).

The paper's point is that a TT-compressed model's WEIGHTS live entirely in
on-chip memory; at serving time the KV cache is the only state that grows,
so it gets the allocator.  Physical memory is a pool of fixed-size pages
``(n_layers, n_pages, KV, P, D)`` shared by every request; each request
owns an ordered list of page ids (its page table) and a contiguous logical
view ``[pos0, length)`` over them.  One :class:`PagedKVCache` instance
covers one GROUP of layers that share a window value (global layers in one
group, ``attn_local`` layers in another) — the layers of a group always
have identical lengths, so one allocation covers all of them and page ids
are shared down the layer axis.

Host-side bookkeeping is plain Python (free-list stack, per-slot tables);
device-side pools are functional JAX arrays the decode step threads
through.  Physical page ids carry NO positional meaning: row ``i`` of
table slot ``p`` is logical position ``pos0 + p*P + i`` — which is what
makes decode output invariant to physical page order (property-tested in
``tests/test_flash_decode.py``).

Windowed layers get RING placement by whole pages: once every row of the
oldest page falls outside the window (``pos0 + P <= length - window``) the
page is freed back to the pool and ``pos0`` advances — the in-page tail
between ``pos0`` and ``length - window`` is masked by the kernel, never
copied.  Page 0 is reserved as the trash target: masked writes from free
decode slots land there, so a dummy lane can never corrupt a live
request's pages.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PagedKVCache", "pages_for", "max_pages_per_request",
           "kv_pool_bytes"]

TRASH_PAGE = 0


def pages_for(rows: int, page_size: int) -> int:
    """Pages needed to hold ``rows`` cache rows."""
    return -(-rows // page_size)


def max_pages_per_request(max_len: int, page_size: int,
                         window: int | None) -> int:
    """Page-table width for one request: a windowed group retains at most
    ``window`` live rows + one partially-evicted page + one partially-
    filled page."""
    if window is None or window >= max_len:
        return pages_for(max_len, page_size)
    return min(pages_for(max_len, page_size),
               pages_for(window, page_size) + 2)


def kv_pool_bytes(n_layers: int, n_pages: int, kv_heads: int,
                  page_size: int, d_head: int, itemsize: int) -> int:
    """HBM footprint of one group's k+v pools (the ledger's DECODE kv row)."""
    return 2 * n_layers * n_pages * kv_heads * page_size * d_head * itemsize


class PagedKVCache:
    """Fixed-page KV cache for one layer group.

    ``slots`` are decode-slot indices (0..max_concurrency-1); the engine
    keys everything by slot, the scheduler decides which request occupies
    which slot.  All mutating methods are host-side bookkeeping only —
    the device pools move exclusively through :meth:`write_prefill` /
    :meth:`write_rows` (functional updates).
    """

    def __init__(self, n_layers: int, kv_heads: int, d_head: int, *,
                 page_size: int, max_len: int, max_concurrency: int,
                 window: int | None = None, dtype=jnp.float32):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_layers = n_layers
        self.page_size = page_size
        self.window = window
        self.max_len = max_len
        self.np_max = max_pages_per_request(max_len, page_size, window)
        n_pages = 1 + max_concurrency * self.np_max  # +1: trash page
        self.n_pages = n_pages
        shape = (n_layers, n_pages, kv_heads, page_size, d_head)
        self.k_pool = jnp.zeros(shape, dtype)
        self.v_pool = jnp.zeros(shape, dtype)
        # LIFO free list; page 0 (TRASH_PAGE) is never handed out.
        self._free: list[int] = list(range(n_pages - 1, TRASH_PAGE, -1))
        self._tables: dict[int, list[int]] = {}
        self._lengths: dict[int, int] = {}
        self._pos0: dict[int, int] = {}

    # -- bookkeeping -----------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def pages_in_use(self) -> set[int]:
        return {p for t in self._tables.values() for p in t}

    def table(self, slot: int) -> list[int]:
        return list(self._tables[slot])

    def length(self, slot: int) -> int:
        return self._lengths[slot]

    def pos0(self, slot: int) -> int:
        return self._pos0[slot]

    def can_admit(self, prompt_len: int) -> bool:
        """True iff a fresh request with this prompt can be allocated now
        (admission control — the scheduler asks before admitting)."""
        return len(self._free) >= pages_for(max(prompt_len, 1),
                                            self.page_size)

    def alloc(self, slot: int, n_rows: int) -> list[int]:
        """Claim pages for a fresh request holding ``n_rows`` rows."""
        if slot in self._tables:
            raise ValueError(f"slot {slot} already allocated")
        need = pages_for(max(n_rows, 1), self.page_size)
        if need > len(self._free):
            raise MemoryError(f"need {need} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(need)]
        self._tables[slot] = pages
        self._lengths[slot] = n_rows
        self._pos0[slot] = 0
        return list(pages)

    def append_target(self, slot: int) -> tuple[int, int]:
        """Grow the slot's view by one row; return the physical
        ``(page_id, row)`` the new KV column must be written to.  Allocates
        a fresh page on a page boundary; windowed groups then retire every
        page that fell wholly out of the window (ring placement)."""
        length = self._lengths[slot]
        pos0 = self._pos0[slot]
        held = length - pos0
        if held == len(self._tables[slot]) * self.page_size:
            if not self._free:
                raise MemoryError("page pool exhausted")
            self._tables[slot].append(self._free.pop())
        pid = self._tables[slot][held // self.page_size]
        row = held % self.page_size
        self._lengths[slot] = length + 1
        if self.window is not None:
            self._evict_out_of_window(slot)
        return pid, row

    def _evict_out_of_window(self, slot: int) -> None:
        while (self._pos0[slot] + self.page_size
               <= self._lengths[slot] - self.window):
            self._free.append(self._tables[slot].pop(0))
            self._pos0[slot] += self.page_size

    def free_slot(self, slot: int) -> None:
        """Return every page the slot holds (request finished/evicted)."""
        for p in self._tables.pop(slot):
            self._free.append(p)
        del self._lengths[slot]
        del self._pos0[slot]

    def device_view(self, n_slots: int) -> tuple[jax.Array, jax.Array,
                                                 jax.Array]:
        """(page_table (n_slots, np_max), lengths, pos0) int32 — the
        scalar-prefetch operands of one flash-decode launch.  Unoccupied
        slots read length 0 and the trash page (never touched: every page
        is dead at length 0)."""
        table = np.full((n_slots, self.np_max), TRASH_PAGE, np.int32)
        lengths = np.zeros((n_slots,), np.int32)
        pos0 = np.zeros((n_slots,), np.int32)
        for slot, pages in self._tables.items():
            table[slot, : len(pages)] = pages
            lengths[slot] = self._lengths[slot]
            pos0[slot] = self._pos0[slot]
        return jnp.asarray(table), jnp.asarray(lengths), jnp.asarray(pos0)

    def write_targets(self, n_slots: int) -> tuple[jax.Array, jax.Array]:
        """(page_ids (n_slots,), rows (n_slots,)) int32 for THIS step's KV
        column, calling :meth:`append_target` on every occupied slot.
        Free slots target the trash page."""
        pids = np.full((n_slots,), TRASH_PAGE, np.int32)
        rows = np.zeros((n_slots,), np.int32)
        for slot in sorted(self._tables):
            pids[slot], rows[slot] = self.append_target(slot)
        return jnp.asarray(pids), jnp.asarray(rows)

    # -- device pools (functional) ---------------------------------------

    def write_prefill(self, slot: int, k_rows: jax.Array,
                      v_rows: jax.Array) -> None:
        """Load a prefill's KV into freshly allocated pages.

        ``k_rows``/``v_rows (n_layers, S, KV, D)`` — the contiguous cache a
        prefill forward produced for this group's layers, walk order.
        Allocates, scatters whole pages, then ring-retires anything already
        outside the window.
        """
        S = k_rows.shape[1]
        pages = self.alloc(slot, S)
        self.k_pool = _scatter_pages(self.k_pool, k_rows, pages,
                                     self.page_size)
        self.v_pool = _scatter_pages(self.v_pool, v_rows, pages,
                                     self.page_size)
        if self.window is not None:
            self._evict_out_of_window(slot)

    def gather(self, slot: int) -> tuple[jax.Array, jax.Array]:
        """(k, v) ``(n_layers, length - pos0, KV, D)`` — the slot's logical
        contiguous view reconstructed from its pages (test oracle for the
        logical→physical mapping; production never materializes this)."""
        pages = self._tables[slot]
        length, pos0 = self._lengths[slot], self._pos0[slot]
        ks = self.k_pool[:, pages]   # (L, n, KV, P, D)
        vs = self.v_pool[:, pages]

        def flat(x):
            L, n, KV, P, D = x.shape
            rows = x.transpose(0, 1, 3, 2, 4).reshape(L, n * P, KV, D)
            return rows[:, : length - pos0]

        return flat(ks), flat(vs)


def _scatter_pages(pool: jax.Array, rows: jax.Array, pages: list[int],
                   page_size: int) -> jax.Array:
    """Write contiguous rows ``(L, S, KV, D)`` into ``pages`` of
    ``pool (L, NP, KV, P, D)`` (tail of the last page zero-padded)."""
    L, S, KV, D = rows.shape
    n = len(pages)
    padded = jnp.pad(rows, ((0, 0), (0, n * page_size - S), (0, 0), (0, 0)))
    vals = padded.reshape(L, n, page_size, KV, D).transpose(0, 1, 3, 2, 4)
    return pool.at[:, jnp.asarray(pages, jnp.int32)].set(
        vals.astype(pool.dtype))
