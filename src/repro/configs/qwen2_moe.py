"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) d_ff=1408(per expert) vocab=151936,
MoE 60e top-4; shared expert = 4x1408 = 5632 hidden.
EP note: 60 experts are NOT divisible by the 16-way model axis — per-expert
FFN dim (1408 = 16x88) is TP-sharded instead (DESIGN.md distribution notes).
`long_500k` SKIPPED: pure full attention.
"""
from repro.configs.base import ModelConfig, MoEConfig, TTConfig, register


@register("qwen2-moe-a2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=1408,
        vocab_size=151936,
        rope_theta=1e6,
        hybrid_pattern=("attn_moe",),
        moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408,
                      shared_d_ff=5632, pad_experts_to=64, every=1, capacity_factor=1.25),
        tt=TTConfig(mode="off", rank=48, embed_rank=64, d=3,
                    scope=("attn", "ffn", "embed", "head")),
        supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention",
    )
