"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768 attn-free (d_ff=0) vocab=50280, ssm_state=128.
Attention-free: `long_500k` RUNS (O(d_state) decode cache).  The paper's TT
technique applies to the in/out projections (DESIGN.md §Arch-applicability);
the SSD scan itself has no weight matrix to compress.
Vocab 50280 padded to 50432 (x256) for 16-way TP of the dense baseline table.
"""
from repro.configs.base import ModelConfig, SSMConfig, TTConfig, register


@register("mamba2-130m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        num_layers=24,
        d_model=768,
        n_heads=24,           # SSD heads = d_inner / head_dim
        n_kv_heads=24,
        d_head=64,
        d_ff=0,
        vocab_size=50280,
        hybrid_pattern=("ssm",),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        pos_embed="none",
        mlp_gated=False,
        tie_embeddings=True,
        tt=TTConfig(mode="off", rank=32, embed_rank=48, d=3,
                    scope=("attn", "embed")),
        supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    )
