"""qwen2.5-14b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5 family].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
`long_500k` SKIPPED: pure full attention.
"""
from repro.configs.base import ModelConfig, TTConfig, register


@register("qwen2.5-14b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        family="dense",
        num_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=13824,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
        hybrid_pattern=("attn",),
        tt=TTConfig(mode="off", rank=64, embed_rank=64, d=3,
                    scope=("attn", "ffn", "embed", "head")),
        supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention",
    )
