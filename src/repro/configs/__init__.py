"""Arch configs: one module per assigned architecture + the paper's model."""
from .base import (
    SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    TTConfig,
    get_config,
    list_archs,
)

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "TTConfig", "ShapeConfig",
    "SHAPES", "get_config", "list_archs",
]
