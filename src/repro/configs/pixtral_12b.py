"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Backbone only per the assignment: the ViT frontend is a STUB —
``input_specs()`` provides precomputed patch embeddings (float) for the
first ``frontend_len`` positions; remaining positions are text tokens.
`long_500k` SKIPPED: pure full attention.
"""
from repro.configs.base import ModelConfig, TTConfig, register


@register("pixtral-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="vlm",
        num_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1e9,
        hybrid_pattern=("attn",),
        frontend="patch",
        frontend_len=1024,   # 1024 patch positions precede the text tokens
        tt=TTConfig(mode="off", rank=64, embed_rank=64, d=3,
                    scope=("attn", "ffn", "embed", "head")),
        supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention",
    )
