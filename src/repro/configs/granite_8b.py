"""granite-8b [dense] — llama-arch code model [arXiv:2405.04324].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
`long_500k` SKIPPED: pure full attention.
"""
from repro.configs.base import ModelConfig, TTConfig, register


@register("granite-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=49152,
        rope_theta=1e4,
        hybrid_pattern=("attn",),
        tt=TTConfig(mode="off", rank=64, embed_rank=64, d=3,
                    scope=("attn", "ffn", "embed", "head")),
        supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention",
    )
