"""musicgen-medium [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048.
The EnCodec frontend is offline (tokens are the model input), so no stub
embedding input is needed: vocab=2048 codebook tokens.  Sinusoidal positions
(as in the paper's decoder), GELU FFN (non-gated).
`long_500k` SKIPPED: pure full attention (quadratic history).
"""
from repro.configs.base import ModelConfig, TTConfig, register


@register("musicgen-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_head=64,
        d_ff=6144,
        vocab_size=2048,
        hybrid_pattern=("attn",),
        pos_embed="sinusoidal",
        act="gelu",
        mlp_gated=False,
        max_seq_len=65536,
        tt=TTConfig(mode="off", rank=48, embed_rank=32, d=3,
                    scope=("attn", "ffn")),
        supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention",
    )
