"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 1:2 [arXiv:2402.19427].

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
Pattern (rec, rec, attn_local) x 8 + (rec, rec) tail = 26 layers; local
attention window 2048.  Sub-quadratic: `long_500k` RUNS (window cache +
O(d) recurrent state).
"""
from repro.configs.base import ModelConfig, TTConfig, register


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_head=256,
        d_ff=7680,
        vocab_size=256000,
        window=2048,
        hybrid_pattern=("rec", "rec", "attn_local"),
        act="gelu",
        tie_embeddings=True,
        tt=TTConfig(mode="off", rank=48, embed_rank=64, d=3,
                    scope=("attn", "ffn", "embed")),
        supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    )
