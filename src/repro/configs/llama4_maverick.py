"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4 family].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, alternating
dense/MoE layers (maverick interleave), one shared expert per MoE layer.
`long_500k` SKIPPED: pure full attention.
"""
from repro.configs.base import ModelConfig, MoEConfig, TTConfig, register


@register("llama4-maverick-400b-a17b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab_size=202048,
        rope_theta=5e5,
        hybrid_pattern=("attn", "attn_moe"),
        moe=MoEConfig(num_experts=128, top_k=1, d_expert=8192,
                      shared_d_ff=8192, every=2, capacity_factor=1.25),
        tt=TTConfig(mode="off", rank=64, embed_rank=64, d=3,
                    scope=("attn", "ffn", "embed", "head")),
        supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention",
    )
