"""The paper's own model (Table II): 2/4/6-encoder transformer for ATIS.

d_hid=768, seq 32, vocab 1000; embedding TTM ((10,10,10),(12,8,8)) rank 30;
attention/FFN/classifier weights TT (12,8,8 | 8,8,12) rank 12; GELU FFN,
non-gated, learned positions, FP32, batch 1 SGD — all per paper Sec. VI.

``config()`` returns the 2-ENC variant; ``config_n(n)`` builds 2/4/6-ENC.
``tt.mode`` toggles the paper's MM baseline vs the tensor-compressed model
(Table III rows).
"""
import dataclasses

from repro.configs.base import ModelConfig, TTConfig, register

PAPER_RANK = 12
PAPER_EMBED_RANK = 30


def config_n(num_layers: int, tt_mode: str = "tt") -> ModelConfig:
    return ModelConfig(
        name="atis-transformer",
        family="dense",
        num_layers=num_layers,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_head=64,
        d_ff=768,            # paper: W1, W2 are (768, 768) (Sec. II-A)
        vocab_size=1000,
        hybrid_pattern=("attn",),
        causal=False,          # paper uses encoder blocks (Fig. 2)
        qkv_bias=True,         # Eq. (1): B_q, B_k, B_v
        tie_embeddings=True,   # classifier model: no separate LM head
        act="gelu",
        mlp_gated=False,
        pos_embed="learned",
        max_seq_len=64,   # paper trains seq 32; learned positions
        dtype="float32",
        tt=TTConfig(mode=tt_mode, rank=PAPER_RANK, embed_rank=PAPER_EMBED_RANK,
                    d=3, flow="btt_fused", scope=("attn", "ffn", "embed"),
                    clamp_ranks=False),  # paper-exact uniform ranks (G_1 = (1,8,12))
        supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="paper model; assigned shapes exercised at arch scale only",
    )


@register("atis-transformer")
def config() -> ModelConfig:
    return config_n(2)
