"""Config system: frozen dataclasses + arch registry.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(name)`` resolves them.  The paper's technique
is a first-class knob (``TTConfig``): any config can run uncompressed
(``tt.mode='off'`` — the paper's MM baseline) or tensor-compressed
(``tt.mode='tt'`` — TT linears + TTM embedding, contraction flow selectable).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

__all__ = [
    "TTConfig", "PrecisionConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
    "ModelConfig", "register", "get_config", "list_archs", "SHAPES",
]


@dataclasses.dataclass(frozen=True)
class PrecisionConfig:
    """Quantized-at-rest storage formats (see ``core.quant``).

    Every compute chain stays f32 on the accumulator side regardless of
    these knobs — they choose what lives in HBM *between* kernel launches:

    * ``param_dtype`` — TT half-factors as the fused kernels see them, and
      the fused-update master parameters ("float32" | "bfloat16" | "int8"
      | "fp8_e4m3").  Scaled formats dequantize inside the kernels.
    * ``act_dtype`` — activations/residuals saved for the backward (the
      flash (O, q, k, v) residuals, the TT layer inputs).  ``None``
      follows the model compute dtype (``ModelConfig.dtype``).
    * ``grad_dtype`` — gradient at-rest storage between BWD and PU
      ("float32" | "bfloat16" | "fp8_e5m2"; int8 gradients are not
      supported — their dynamic range collapses under a single scale).
    * ``scale_granularity`` — "per_tile": one f32 scale per packed
      ``(BLOCK_ROWS, LANES)`` block in the fused update (the half-factors
      are per-tensor either way: each IS one VMEM tile); "per_tensor":
      one scale per packed buffer.
    """

    param_dtype: str = "float32"
    act_dtype: str | None = None
    grad_dtype: str = "float32"
    scale_granularity: str = "per_tile"   # "per_tile" | "per_tensor"

    def resolved_act(self, model_dtype: str) -> str:
        return self.act_dtype or model_dtype

    @property
    def quantized(self) -> bool:
        from repro.core.quant import needs_scale
        return (needs_scale(self.param_dtype)
                or (self.act_dtype is not None
                    and needs_scale(self.act_dtype)))


@dataclasses.dataclass(frozen=True)
class TTConfig:
    """Paper-technique knobs (TT linear + TTM embedding)."""

    mode: str = "off"             # "off" (dense MM baseline) | "tt"
    rank: int = 64                # TT rank for weight matrices
    embed_rank: int = 64          # TTM rank for the embedding table
    d: int = 3                    # tensorization order (2d cores per matrix)
    flow: str = "btt_fused"       # "rl" | "btt" | "btt_fused" | "kernel"
    fused_bwd: bool = True        # flow="kernel": run the BWD stage as the
                                  # single fused Pallas kernel (btt_backward)
    scope: tuple[str, ...] = ("attn", "ffn", "embed")  # what gets compressed
    clamp_ranks: bool = True      # False = paper-exact uniform interior ranks
    precision: PrecisionConfig = PrecisionConfig()  # at-rest storage formats

    def on(self, part: str) -> bool:
        return self.mode == "tt" and part in self.scope


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    shared_d_ff: int = 0          # shared-expert hidden dim (0 = none)
    every: int = 1                # MoE layer every N layers (1 = all layers)
    capacity_factor: float = 1.25
    # Pad the expert dimension to a TP-divisible count (dummy experts are
    # never routed to).  Trades a few % parameter waste for clean expert
    # parallelism — 60 experts on a 16-way axis otherwise force per-expert
    # FFN-TP whose all-reduces dominate (EXPERIMENTS.md §Perf iteration 3).
    pad_experts_to: int | None = None

    @property
    def padded_experts(self) -> int:
        return max(self.pad_experts_to or 0, self.num_experts)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell: what gets lowered for the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int | None = None     # sliding-window size for local-attn layers
    # Training/prefill attention through the fused flash kernels (forward
    # saves only (O, m, l); backward is one Pallas kernel — no S×S
    # probability tensor).  Falls back to blockwise_attention per shape
    # when the backward working set exceeds the kernel VMEM budget.
    fused_attn: bool = False
    # FFN blocks (TT-compressed, tt.flow="kernel" only — like
    # tt.fused_bwd, this refines the kernel flow) as the fused megakernel:
    # both TT linears + activation in ONE pallas_call per direction, the
    # (K, d_ff) hidden state resident in VMEM scratch, backward
    # recomputing it from x (FFN residuals shrink to the layer input).
    # Falls back to the two-call path per shape when the working set
    # exceeds the kernel VMEM budget (kernels.btt_ffn.ffn_vmem_fits) or a
    # model-parallel mesh is in scope.
    fused_ffn: bool = False
    # block structure
    hybrid_pattern: tuple[str, ...] = ("attn",)   # cycle of "attn"|"rec"|"ssm"
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # modality frontend stub ([audio]/[vlm]): float embeddings for a prefix
    frontend: str | None = None   # None | "patch"
    frontend_len: int = 0
    # misc
    causal: bool = True           # False = encoder (paper's ATIS classifier)
    norm_eps: float = 1e-6
    attn_q_chunk: int = 512       # blockwise-attention tiling (0 = single block)
    attn_kv_chunk: int = 1024
    act: str = "silu"             # "silu" (SwiGLU) | "gelu" (plain MLP)
    mlp_gated: bool = True
    tie_embeddings: bool = False
    pos_embed: str = "rope"       # "rope" | "learned" | "sinusoidal" | "none"
    max_seq_len: int = 524288
    dtype: str = "bfloat16"
    tt: TTConfig = TTConfig()
    # which assigned shapes apply; None entry in a cell table => documented skip
    supported_shapes: tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    skip_notes: str = ""

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 (16-way TP x 128-lane tiles)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def attn_dims(self) -> tuple[int, int, int]:
        q = self.n_heads * self.d_head
        kv = self.n_kv_heads * self.d_head
        return q, kv, self.d_model

    def with_tt(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, tt=dataclasses.replace(self.tt, **kw))

    def with_precision(self, **kw) -> "ModelConfig":
        """Replace fields of ``tt.precision`` (the at-rest storage tier)."""
        return self.with_tt(
            precision=dataclasses.replace(self.tt.precision, **kw))

    def with_fused_attn(self, on: bool = True) -> "ModelConfig":
        return dataclasses.replace(self, fused_attn=on)

    def with_fused_ffn(self, on: bool = True) -> "ModelConfig":
        return dataclasses.replace(self, fused_ffn=on)

    def scaled_down(self, **overrides) -> "ModelConfig":
        """Reduced config of the same family for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 2),
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=64,
            d_ff=512,
            vocab_size=512,
            frontend_len=min(self.frontend_len, 16),
            max_seq_len=512,
            dtype="float32",
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4),
                d_expert=128, shared_d_ff=128 if self.moe.shared_d_ff else 0)
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=32, chunk=64)
        if self.window is not None:
            small["window"] = 128
        small.update(overrides)
        return dataclasses.replace(self, **small)


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}

ARCHS = (
    "mamba2-130m", "musicgen-medium", "qwen3-8b", "granite-8b",
    "qwen2.5-14b", "llama3-8b", "recurrentgemma-2b",
    "llama4-maverick-400b-a17b", "qwen2-moe-a2.7b", "pixtral-12b",
    "atis-transformer",
)

_MODULES = {
    "mamba2-130m": "mamba2_130m",
    "musicgen-medium": "musicgen_medium",
    "qwen3-8b": "qwen3_8b",
    "granite-8b": "granite_8b",
    "qwen2.5-14b": "qwen2_5_14b",
    "llama3-8b": "llama3_8b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "qwen2-moe-a2.7b": "qwen2_moe",
    "pixtral-12b": "pixtral_12b",
    "atis-transformer": "atis_transformer",
}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        mod = _MODULES.get(name)
        if mod is None:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]()


def list_archs() -> tuple[str, ...]:
    return ARCHS
