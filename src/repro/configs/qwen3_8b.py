"""qwen3-8b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B].

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
`long_500k` SKIPPED: pure full attention.
"""
from repro.configs.base import ModelConfig, TTConfig, register


@register("qwen3-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=12288,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        hybrid_pattern=("attn",),
        tt=TTConfig(mode="off", rank=64, embed_rank=64, d=3,
                    scope=("attn", "ffn", "embed", "head")),
        supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention",
    )
