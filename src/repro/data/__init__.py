"""Data pipelines: deterministic, *seekable* synthetic datasets.

Every batch is a pure function of ``(seed, step)`` — there is no pipeline
state to checkpoint or replay, so fault-tolerant restart is exact by
construction (resume at step k reproduces the byte-identical batch stream),
and elastic re-sharding only has to re-slice the global batch.
"""
from .atis import AtisGrammar, atis_batch, ATIS_NUM_INTENTS, ATIS_NUM_SLOTS
from .synthetic import lm_batch, lm_eval_batch

__all__ = [
    "AtisGrammar", "atis_batch", "ATIS_NUM_INTENTS", "ATIS_NUM_SLOTS",
    "lm_batch", "lm_eval_batch",
]
