"""Deterministic seekable LM token stream (generic arch shapes).

Batches are pure functions of ``(seed, step)`` via counter-based RNG
(numpy ``SeedSequence((seed, step))``): skip-ahead restart and multi-host
determinism come for free.  Tokens follow a Zipf-ish marginal with a
first-order Markov structure so perplexity is learnable (loss decreases),
which the integration tests assert.
"""
from __future__ import annotations

import numpy as np

__all__ = ["lm_batch", "lm_eval_batch"]


def _rng(seed: int, step: int, stream: int = 0) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence((seed, stream, step)))


def _markov_tables(seed: int, vocab: int, branch: int = 16):
    """Fixed per-seed Markov structure: each token has ``branch`` likely
    successors.  Cached per (seed, vocab)."""
    key = (seed, vocab, branch)
    tbl = _markov_tables._cache.get(key)
    if tbl is None:
        g = np.random.default_rng(np.random.SeedSequence((seed, 0xA715)))
        succ = g.integers(0, vocab, size=(vocab, branch), dtype=np.int32)
        tbl = succ
        _markov_tables._cache[key] = tbl
    return tbl


_markov_tables._cache = {}


def lm_batch(seed: int, step: int, batch: int, seq_len: int, vocab: int,
             *, stream: int = 0) -> dict:
    """One global batch: {"tokens" (B,S), "labels" (B,S), "mask" (B,S)}.

    labels[t] = tokens[t+1] (next-token prediction); final position masked.
    """
    g = _rng(seed, step, stream)
    succ = _markov_tables(seed, vocab)
    branch = succ.shape[1]
    toks = np.empty((batch, seq_len + 1), np.int32)
    toks[:, 0] = g.integers(0, vocab, size=batch)
    # 85% Markov successor, 15% uniform noise — learnable but not trivial.
    choices = g.integers(0, branch, size=(batch, seq_len))
    noise = g.integers(0, vocab, size=(batch, seq_len), dtype=np.int32)
    take_noise = g.random((batch, seq_len)) < 0.15
    for t in range(seq_len):
        nxt = succ[toks[:, t], choices[:, t]]
        toks[:, t + 1] = np.where(take_noise[:, t], noise[:, t], nxt)
    mask = np.ones((batch, seq_len), np.float32)
    return {
        "tokens": toks[:, :seq_len],
        "labels": toks[:, 1:],
        "mask": mask,
    }


def lm_eval_batch(seed: int, step: int, batch: int, seq_len: int,
                  vocab: int) -> dict:
    """Held-out stream (disjoint RNG stream from training)."""
    return lm_batch(seed, step, batch, seq_len, vocab, stream=1)
