"""Synthetic ATIS-style dataset (paper Sec. VI — intent + slot filling).

The real ATIS corpus is not redistributable offline; we generate a synthetic
stand-in with matched statistics (vocab 1000, seq 32, 26 intents, 120 slot
labels — DESIGN.md §Known-deviations).  The *reproduction target* is the
paper's Table III/Fig. 13 claim: tensor-compressed training reaches accuracy
parity with uncompressed matrix training — which requires a dataset whose
structure a small transformer can actually learn:

* intent: each intent owns a few "keyword" tokens; an utterance contains
  keywords of exactly one intent → intent is inferable by token aggregation.
* slots: a fixed (seed-derived) token→slot map, with slot-bearing tokens
  introduced by a small set of "trigger" tokens (e.g. "to <city>") so slot
  labels depend on local context, not just token identity.

Batches are pure functions of ``(seed, split, step)`` — seekable restart.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["AtisGrammar", "atis_batch", "ATIS_NUM_INTENTS", "ATIS_NUM_SLOTS"]

ATIS_VOCAB = 1000
ATIS_SEQ = 32
ATIS_NUM_INTENTS = 26
ATIS_NUM_SLOTS = 120  # label 0 = "O" (outside)


@dataclasses.dataclass(frozen=True)
class AtisGrammar:
    """Seed-derived fixed task structure."""

    seed: int
    vocab: int = ATIS_VOCAB
    num_intents: int = ATIS_NUM_INTENTS
    num_slots: int = ATIS_NUM_SLOTS

    def tables(self):
        key = (self.seed, self.vocab, self.num_intents, self.num_slots)
        cached = AtisGrammar._cache.get(key)
        if cached is not None:
            return cached
        g = np.random.default_rng(np.random.SeedSequence((self.seed, 0x4715)))
        # Token bands: [0, 600) filler, [600, 730) intent keywords (5 per
        # intent), [730, 1000) slot-value tokens.
        kw = 600 + np.arange(self.num_intents * 5).reshape(self.num_intents, 5)
        slot_vals = np.arange(730, self.vocab)
        # Each slot-value token maps to one of slots 1..num_slots-1.
        val_slot = g.integers(1, self.num_slots, size=slot_vals.size).astype(np.int32)
        # Trigger tokens (from filler band) that promote the NEXT token's slot.
        triggers = g.choice(600, size=40, replace=False).astype(np.int32)
        cached = (kw.astype(np.int32), slot_vals.astype(np.int32), val_slot,
                  triggers)
        AtisGrammar._cache[key] = cached
        return cached


AtisGrammar._cache = {}  # class-level memo (not a dataclass field)


def atis_batch(grammar: AtisGrammar, split: str, step: int, batch: int,
               seq_len: int = ATIS_SEQ) -> dict:
    """{"tokens" (B,S) int32, "intent" (B,), "slots" (B,S)}.

    ``split``: "train" | "test" — disjoint RNG streams.
    """
    kw, slot_vals, val_slot, triggers = grammar.tables()
    stream = {"train": 0, "test": 1}[split]
    g = np.random.default_rng(
        np.random.SeedSequence((grammar.seed, stream, step)))

    B, S = batch, seq_len
    intent = g.integers(0, grammar.num_intents, size=B).astype(np.int32)
    tokens = g.integers(0, 600, size=(B, S)).astype(np.int32)  # filler base
    slots = np.zeros((B, S), np.int32)

    # 2-4 intent keywords per utterance at random positions (not position 0:
    # position 0 acts as [CLS] for the intent head).
    n_kw = g.integers(2, 5, size=B)
    for i in range(B):
        pos = g.choice(np.arange(1, S), size=n_kw[i], replace=False)
        which = g.integers(0, kw.shape[1], size=n_kw[i])
        tokens[i, pos] = kw[intent[i], which]

    # Trigger -> slot-value bigrams: ~4 per utterance.
    n_sv = g.integers(2, 6, size=B)
    for i in range(B):
        pos = g.choice(np.arange(1, S - 1), size=n_sv[i], replace=False)
        vi = g.integers(0, slot_vals.size, size=n_sv[i])
        tokens[i, pos] = triggers[g.integers(0, triggers.size, size=n_sv[i])]
        tokens[i, pos + 1] = slot_vals[vi]
        slots[i, pos + 1] = val_slot[vi]

    return {"tokens": tokens, "intent": intent, "slots": slots}
